//! The DES machine: virtual cores, scheduler, cache directory, memory bus.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::faults::{FaultAction, FaultPlan, InjectedKill};
use crate::os::{AffinityMode, OsProfile};

/// Memory-hierarchy cost constants (nanoseconds), matching the L2 model's
/// calibration (python/compile/model.py DEFAULTS).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemCosts {
    /// On-core cache hit.
    pub hit_ns: u64,
    /// Memory-bus service time per line transfer (miss / coherence).
    pub bus_ns: u64,
    /// Extra cost of an atomic read-modify-write over a plain access.
    pub rmw_extra_ns: u64,
    /// Pure-CPU overhead charged per API call by the runtime glue.
    pub api_overhead_ns: u64,
}

impl Default for MemCosts {
    fn default() -> Self {
        MemCosts { hit_ns: 2, bus_ns: 60, rmw_extra_ns: 12, api_overhead_ns: 700 }
    }
}

/// Machine configuration.
#[derive(Debug, Clone, Copy)]
pub struct MachineCfg {
    /// Number of virtual cores.
    pub cores: usize,
    /// OS cost profile (linux-rt / windows).
    pub profile: OsProfile,
    /// Task placement policy.
    pub affinity: AffinityMode,
    /// Memory costs.
    pub mem: MemCosts,
}

impl MachineCfg {
    /// Convenience constructor with default memory costs.
    pub fn new(cores: usize, profile: OsProfile, affinity: AffinityMode) -> Self {
        MachineCfg { cores, profile, affinity, mem: MemCosts::default() }
    }
}

/// Counters exposed after a run (all in virtual nanoseconds / counts).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MachineStats {
    /// Virtual makespan: max task clock at completion.
    pub virtual_ns: u64,
    /// Total bus busy time (utilization = busy / virtual).
    pub bus_busy_ns: u64,
    /// Cache hits.
    pub hits: u64,
    /// Cache misses (bus transactions).
    pub misses: u64,
    /// Context switches performed.
    pub ctx_switches: u64,
    /// Cross-core task migrations.
    pub migrations: u64,
    /// Kernel entries (contended lock paths, wakes).
    pub syscalls: u64,
    /// Atomic read-modify-write operations (CAS / fetch_add / fetch_or /
    /// fetch_and) across all tasks — the shared-counter contention signal
    /// the work-stealing gates assert on.
    pub rmws: u64,
}

impl MachineStats {
    /// Bus utilization in [0,1].
    pub fn bus_utilization(&self) -> f64 {
        if self.virtual_ns == 0 {
            0.0
        } else {
            self.bus_busy_ns as f64 / self.virtual_ns as f64
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TaskState {
    /// Waiting in a core's ready queue.
    Ready,
    /// Occupant of a core (may or may not be globally executing).
    Current,
    /// Asleep on a futex address.
    Blocked,
    /// Finished.
    Done,
}

struct Tcb {
    clock: u64,
    core: usize,
    pinned: bool,
    state: TaskState,
    quantum_start: u64,
    /// Priced operations executed so far (the fault-plan index space).
    ops: u64,
    /// Atomic read-modify-write operations executed so far (subset of
    /// `ops`); read by the zero-CAS steady-state gates.
    rmws: u64,
    /// Virtual deadline for a timed futex wait, if any.
    wake_at: Option<u64>,
}

struct Core {
    ready: VecDeque<usize>,
    current: Option<usize>,
    /// Last task that ran here (context-switch detection).
    last: Option<usize>,
    time: u64,
}

#[derive(Default, Clone, Copy)]
struct Line {
    /// Bitmask of cores with a valid copy.
    sharers: u64,
    /// Core with write (exclusive) ownership, if dirty.
    owner: Option<usize>,
}

struct State {
    tasks: Vec<Tcb>,
    cores: Vec<Core>,
    lines: HashMap<u64, Line>,
    futex: BTreeMap<u64, VecDeque<usize>>,
    bus_free_at: u64,
    running: Option<usize>,
    live: usize,
    aborted: bool,
    stats: MachineStats,
    faults: Option<FaultPlan>,
}

struct Shared {
    cfg: MachineCfg,
    state: Mutex<State>,
    cv: Condvar,
}

/// Handle to a simulated SMP machine. Clone freely.
#[derive(Clone)]
pub struct Machine {
    shared: Arc<Shared>,
}

/// Lock that survives poisoning (a panicking task — e.g. the deadlock
/// detector — must not turn every other lock().unwrap() into a second,
/// unrelated panic).
fn lock(shared: &Shared) -> std::sync::MutexGuard<'_, State> {
    shared.state.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a>(
    shared: &'a Shared,
    guard: std::sync::MutexGuard<'a, State>,
) -> std::sync::MutexGuard<'a, State> {
    shared.cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// Global synthetic address allocator for sim atoms / payload regions.
/// Each allocation gets its own cache line; regions get a contiguous range.
static NEXT_ADDR: AtomicU64 = AtomicU64::new(0x1000);

/// Allocate a synthetic address range of `bytes`, cache-line granular.
pub(crate) fn alloc_region(bytes: usize) -> u64 {
    let lines = ((bytes + 63) / 64).max(1) as u64;
    NEXT_ADDR.fetch_add(lines * 64, Ordering::Relaxed)
}

impl Machine {
    /// Create a machine with no tasks.
    pub fn new(cfg: MachineCfg) -> Self {
        assert!(cfg.cores >= 1 && cfg.cores <= 64, "1..=64 cores");
        let cores = (0..cfg.cores)
            .map(|_| Core { ready: VecDeque::new(), current: None, last: None, time: 0 })
            .collect();
        Machine {
            shared: Arc::new(Shared {
                cfg,
                state: Mutex::new(State {
                    tasks: Vec::new(),
                    cores,
                    lines: HashMap::new(),
                    futex: BTreeMap::new(),
                    bus_free_at: 0,
                    running: None,
                    live: 0,
                    aborted: false,
                    stats: MachineStats::default(),
                    faults: None,
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Machine configuration.
    pub fn cfg(&self) -> MachineCfg {
        self.shared.cfg
    }

    /// Spawn a simulated task. Must be called before [`Machine::run`].
    /// The closure runs on its own OS thread under the machine's monitor,
    /// with the thread-local task context installed so `SimWorld`
    /// operations charge this machine.
    pub fn spawn<F>(&self, f: F) -> JoinHandle<()>
    where
        F: FnOnce() + Send + 'static,
    {
        let id;
        {
            let mut st = lock(&self.shared);
            id = st.tasks.len();
            let core = match self.shared.cfg.affinity {
                AffinityMode::SingleCore => 0,
                AffinityMode::PinnedSpread | AffinityMode::Free => id % self.shared.cfg.cores,
            };
            let pinned = self.shared.cfg.affinity != AffinityMode::Free;
            st.tasks.push(Tcb {
                clock: 0,
                core,
                pinned,
                state: TaskState::Ready,
                quantum_start: 0,
                ops: 0,
                rmws: 0,
                wake_at: None,
            });
            st.cores[core].ready.push_back(id);
            st.live += 1;
        }
        let machine = self.clone();
        std::thread::spawn(move || {
            super::world::install_ctx(machine.clone(), id);
            machine.wait_until_running(id);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            super::world::clear_ctx();
            match result {
                Ok(()) => machine.finish(id, false),
                // A planned fault-injection kill: clean single-task death.
                // The machine keeps scheduling the survivors so recovery
                // paths can be exercised.
                Err(e) if e.downcast_ref::<InjectedKill>().is_some() => {
                    machine.finish(id, false);
                }
                Err(e) => {
                    machine.finish(id, true);
                    std::panic::resume_unwind(e);
                }
            }
        })
    }

    /// Install a fault plan consulted on every priced operation. Call
    /// before [`Machine::run`]; events fire keyed on `(task, op index)`.
    pub fn set_faults(&self, plan: FaultPlan) {
        let mut st = lock(&self.shared);
        st.faults = Some(plan);
    }

    /// Priced operations task `id` has executed so far (unpriced read —
    /// used by fault-sweep probes to measure an op-index window).
    pub fn task_ops(&self, id: usize) -> u64 {
        lock(&self.shared).tasks[id].ops
    }

    /// Atomic RMW operations task `id` has executed so far (unpriced
    /// read — the zero-shared-CAS steady-state gates diff this across a
    /// drain window; 0 for unknown ids).
    pub fn task_rmws(&self, id: usize) -> u64 {
        lock(&self.shared).tasks.get(id).map_or(0, |t| t.rmws)
    }

    /// Virtual clock of task `id` (unpriced read — the timestamp source
    /// for the observability plane's [`World::timestamp_peek`] on the
    /// sim plane; 0 for unknown ids).
    ///
    /// [`World::timestamp_peek`]: crate::lockfree::World::timestamp_peek
    pub fn task_clock(&self, id: usize) -> u64 {
        lock(&self.shared).tasks.get(id).map_or(0, |t| t.clock)
    }

    /// True once task `id` has finished (normally or by injected kill).
    pub fn task_done(&self, id: usize) -> bool {
        let st = lock(&self.shared);
        st.tasks.get(id).map_or(false, |t| t.state == TaskState::Done)
    }

    /// Number of tasks spawned so far.
    pub fn task_count(&self) -> usize {
        lock(&self.shared).tasks.len()
    }

    /// Start scheduling and block until every task finished. Returns the
    /// machine statistics. Panics if any task panicked.
    pub fn run(&self, handles: Vec<JoinHandle<()>>) -> MachineStats {
        {
            let mut st = lock(&self.shared);
            self.schedule(&mut st);
        }
        self.shared.cv.notify_all();
        let mut payloads = Vec::new();
        for h in handles {
            if let Err(e) = h.join() {
                payloads.push(e);
            }
        }
        if !payloads.is_empty() {
            // Prefer the root cause over secondary "machine aborted" panics
            // raised by tasks that were merely descheduled during shutdown.
            let is_secondary = |p: &Box<dyn std::any::Any + Send>| {
                p.downcast_ref::<String>()
                    .map(|s| s.contains("machine aborted"))
                    .unwrap_or(false)
            };
            let idx = payloads.iter().position(|p| !is_secondary(p)).unwrap_or(0);
            std::panic::resume_unwind(payloads.swap_remove(idx));
        }
        let st = lock(&self.shared);
        st.stats
    }

    /// Convenience: spawn `n` closures produced by `make` and run.
    pub fn run_tasks<F>(&self, n: usize, mut make: impl FnMut(usize) -> F) -> MachineStats
    where
        F: FnOnce() + Send + 'static,
    {
        let handles: Vec<_> = (0..n).map(|i| self.spawn(make(i))).collect();
        self.run(handles)
    }

    // -- monitor internals -------------------------------------------------

    fn wait_until_running(&self, me: usize) {
        let mut st = lock(&self.shared);
        while st.running != Some(me) && !st.aborted {
            st = wait(&self.shared, st);
        }
    }

    /// Execute one instrumented operation under the monitor.
    ///
    /// `f` runs at the task's current virtual instant (the linearization
    /// point: reads/writes of real memory inside `f` are serialized by the
    /// monitor); it returns the operation's result. Afterwards the
    /// scheduler may hand the (real) CPU to another task; the call returns
    /// once this task is scheduled again.
    pub(crate) fn op<R>(&self, f: impl FnOnce(&mut OpCtx<'_>) -> R) -> R {
        let me = super::world::current_task(self);
        let mut st = lock(&self.shared);
        assert!(!st.aborted, "machine aborted");
        assert_eq!(st.running, Some(me), "op from task not scheduled");
        // Fault hook: a plain counter bump plus (when a plan is armed) one
        // map lookup — nothing here is priced, so fault-free runs keep
        // identical virtual-time results. Events fire *before* `f`, which
        // is what makes a `Kill` land inside the enter/exit window of the
        // operation whose op index it names.
        let k = st.tasks[me].ops;
        st.tasks[me].ops += 1;
        if let Some(action) = st.faults.as_mut().and_then(|p| p.take(me, k)) {
            match action {
                FaultAction::Kill => {
                    drop(st);
                    // resume_unwind skips the panic hook: injected deaths
                    // are planned, not error spew. spawn() recognises the
                    // payload and finishes the task cleanly.
                    std::panic::resume_unwind(Box::new(InjectedKill));
                }
                FaultAction::Stall(ns) => {
                    st.tasks[me].clock += ns;
                    st = self.reschedule(st, me);
                }
                FaultAction::Delay(ns) => {
                    st.tasks[me].clock += ns;
                    let core = st.tasks[me].core;
                    if !st.cores[core].ready.is_empty() {
                        st.cores[core].time = st.tasks[me].clock;
                        st.tasks[me].state = TaskState::Ready;
                        st.cores[core].ready.push_back(me);
                        st.cores[core].current = None;
                    }
                    st = self.reschedule(st, me);
                }
            }
        }
        let r = {
            let mut ctx = OpCtx { st: &mut st, cfg: &self.shared.cfg, me };
            f(&mut ctx)
        };
        let _ = self.reschedule(st, me);
        r
    }

    /// Run a scheduling pass and, if the machine was handed to another
    /// task, block until this task is scheduled again.
    fn reschedule<'a>(
        &'a self,
        mut st: std::sync::MutexGuard<'a, State>,
        me: usize,
    ) -> std::sync::MutexGuard<'a, State> {
        self.schedule(&mut st);
        if st.running != Some(me) {
            self.shared.cv.notify_all();
            while st.running != Some(me) && !st.aborted {
                st = wait(&self.shared, st);
            }
            if st.aborted && st.running != Some(me) {
                // Unblock panicking shutdown.
                drop(st);
                panic!("machine aborted while task {me} was descheduled");
            }
        }
        st
    }

    fn finish(&self, me: usize, panic: bool) {
        let mut st = lock(&self.shared);
        if panic {
            st.aborted = true;
        }
        let core = st.tasks[me].core;
        st.tasks[me].state = TaskState::Done;
        let clock = st.tasks[me].clock;
        st.cores[core].time = st.cores[core].time.max(clock);
        if st.cores[core].current == Some(me) {
            st.cores[core].current = None;
        } else {
            // Was in a queue (e.g. finished immediately after spawn).
            st.cores[core].ready.retain(|&t| t != me);
        }
        st.live -= 1;
        st.stats.virtual_ns = st.stats.virtual_ns.max(clock);
        if !st.aborted {
            self.schedule(&mut st);
        }
        self.shared.cv.notify_all();
    }

    /// Scheduling pass: fill cores, rotate expired quanta, pick the global
    /// min-clock occupant as the running task, and expire timed futex
    /// waits whose virtual deadline has come due.
    fn schedule(&self, st: &mut State) {
        let cfg = &self.shared.cfg;
        loop {
            // Fill empty cores and rotate expired quanta until stable.
            loop {
                let mut changed = false;
                for c in 0..st.cores.len() {
                    if st.cores[c].current.is_none() {
                        if let Some(t) = st.cores[c].ready.pop_front() {
                            let switch = st.cores[c].last != Some(t);
                            if switch {
                                st.cores[c].time += cfg.profile.context_switch_ns;
                                st.stats.ctx_switches += 1;
                            }
                            let start = st.tasks[t].clock.max(st.cores[c].time);
                            st.tasks[t].clock = start;
                            st.tasks[t].quantum_start = start;
                            st.tasks[t].state = TaskState::Current;
                            st.cores[c].current = Some(t);
                            st.cores[c].last = Some(t);
                            changed = true;
                        }
                    } else {
                        let t = st.cores[c].current.unwrap();
                        let ran = st.tasks[t].clock.saturating_sub(st.tasks[t].quantum_start);
                        if ran >= cfg.profile.quantum_ns && !st.cores[c].ready.is_empty() {
                            st.cores[c].time = st.tasks[t].clock;
                            st.tasks[t].state = TaskState::Ready;
                            st.cores[c].ready.push_back(t);
                            st.cores[c].current = None;
                            changed = true;
                        }
                    }
                }
                if !changed {
                    break;
                }
            }
            // Pick the min-clock occupant (tie-break: lowest task id).
            st.running = st
                .cores
                .iter()
                .filter_map(|c| c.current)
                .min_by_key(|&t| (st.tasks[t].clock, t));
            // Timed futex waits: wake the earliest-deadline sleeper when
            // its deadline precedes the would-be running task's clock (so
            // timeout handling happens at the right virtual instant), or
            // when nothing else is runnable (the idle machine advances to
            // the deadline instead of declaring deadlock).
            let next_timed = st
                .tasks
                .iter()
                .enumerate()
                .filter(|(_, t)| t.state == TaskState::Blocked)
                .filter_map(|(i, t)| t.wake_at.map(|w| (w, i)))
                .min();
            if let Some((wake_at, t)) = next_timed {
                let due = match st.running {
                    None => true,
                    Some(r) => wake_at < st.tasks[r].clock,
                };
                if due {
                    for q in st.futex.values_mut() {
                        q.retain(|&x| x != t);
                    }
                    st.futex.retain(|_, q| !q.is_empty());
                    let tcb = &mut st.tasks[t];
                    tcb.wake_at = None;
                    tcb.state = TaskState::Ready;
                    tcb.clock = tcb.clock.max(wake_at);
                    let core = tcb.core;
                    st.cores[core].ready.push_back(t);
                    continue;
                }
            }
            break;
        }
        if st.running.is_none() && st.live > 0 {
            // All live tasks blocked: deadlock in the simulated program.
            let waiting: Vec<_> = st.futex.iter().map(|(a, q)| (*a, q.len())).collect();
            st.aborted = true;
            self.shared.cv.notify_all();
            panic!("simulated deadlock: {} live tasks all blocked; futex queues: {waiting:?}", st.live);
        }
    }
}

/// Mutable view of the machine passed to instrumented operations.
pub(crate) struct OpCtx<'a> {
    st: &'a mut State,
    cfg: &'a MachineCfg,
    me: usize,
}

impl OpCtx<'_> {
    /// This task's virtual clock.
    pub fn now(&self) -> u64 {
        self.st.tasks[self.me].clock
    }

    /// Charge pure CPU time.
    pub fn charge(&mut self, ns: u64) {
        self.st.tasks[self.me].clock += ns;
    }

    /// One cache-line access; models MESI-lite coherence + bus FIFO.
    pub fn mem_access(&mut self, addr: u64, write: bool, rmw: bool) {
        let line_addr = addr >> 6;
        let core = self.st.tasks[self.me].core;
        let bit = 1u64 << core;
        let line = self.st.lines.entry(line_addr).or_default();
        let hit = if write {
            line.owner == Some(core) && line.sharers == bit
        } else {
            line.sharers & bit != 0
        };
        if hit {
            self.st.tasks[self.me].clock += self.cfg.mem.hit_ns;
            self.st.stats.hits += 1;
        } else {
            // Miss: line transfer over the shared bus (FIFO in virtual time).
            let t = self.st.tasks[self.me].clock;
            let start = t.max(self.st.bus_free_at);
            let end = start + self.cfg.mem.bus_ns;
            self.st.bus_free_at = end;
            self.st.stats.bus_busy_ns += self.cfg.mem.bus_ns;
            self.st.tasks[self.me].clock = end + self.cfg.mem.hit_ns;
            self.st.stats.misses += 1;
            let line = self.st.lines.get_mut(&line_addr).unwrap();
            if write {
                line.sharers = bit;
                line.owner = Some(core);
            } else {
                line.sharers |= bit;
                if line.owner != Some(core) {
                    line.owner = None;
                }
            }
        }
        if rmw {
            self.st.tasks[self.me].clock += self.cfg.mem.rmw_extra_ns;
            self.st.tasks[self.me].rmws += 1;
            self.st.stats.rmws += 1;
        }
        if write && !rmw {
            // Plain store invalidates other sharers (no extra latency charge
            // beyond the transfer; invalidation traffic is folded into bus_ns).
            let line = self.st.lines.get_mut(&line_addr).unwrap();
            line.sharers = bit;
            line.owner = Some(core);
        }
    }

    /// Bulk payload access (message copy): sequential line accesses.
    pub fn touch(&mut self, region: u64, bytes: usize, write: bool) {
        let lines = ((bytes + 63) / 64).max(1);
        for i in 0..lines {
            self.mem_access(region + (i as u64) * 64, write, false);
        }
    }

    /// Charge the profile's uncontended lock entry cost. On profiles with
    /// kernel dispatcher locks (Windows) even the fast path is a syscall.
    pub fn lock_fast(&mut self) {
        if self.cfg.profile.kernel_always {
            self.syscall();
        } else {
            self.charge(self.cfg.profile.lock_fast_ns);
        }
    }

    /// Charge a kernel entry.
    pub fn syscall(&mut self) {
        self.charge(self.cfg.profile.syscall_ns);
        self.st.stats.syscalls += 1;
    }

    /// Explicit yield: charge and rotate this core's occupancy.
    pub fn yield_now(&mut self) {
        self.charge(self.cfg.profile.yield_ns);
        let core = self.st.tasks[self.me].core;
        if !self.st.cores[core].ready.is_empty() {
            self.st.cores[core].time = self.st.tasks[self.me].clock;
            self.st.tasks[self.me].state = TaskState::Ready;
            self.st.cores[core].ready.push_back(self.me);
            self.st.cores[core].current = None;
        }
    }

    /// Sleep on `addr` if `still` holds (checked race-free under the
    /// monitor). The task parks until another task calls `futex_wake`.
    pub fn futex_wait(&mut self, addr: u64, still: impl FnOnce() -> bool) {
        self.futex_wait_deadline(addr, None, still)
    }

    /// Like [`OpCtx::futex_wait`], but with an optional absolute virtual
    /// deadline: the scheduler wakes the task spuriously once its clock
    /// would pass `deadline` (callers re-check their condition and the
    /// time, exactly like a real `FUTEX_WAIT` timeout).
    pub fn futex_wait_deadline(
        &mut self,
        addr: u64,
        deadline: Option<u64>,
        still: impl FnOnce() -> bool,
    ) {
        if !still() {
            return;
        }
        let core = self.st.tasks[self.me].core;
        self.st.tasks[self.me].state = TaskState::Blocked;
        self.st.tasks[self.me].wake_at = deadline;
        self.st.futex.entry(addr).or_default().push_back(self.me);
        self.st.cores[core].time = self.st.tasks[self.me].clock;
        self.st.cores[core].current = None;
    }

    /// Wake up to `n` sleepers on `addr`; returns how many woke.
    pub fn futex_wake(&mut self, addr: u64, n: usize) -> usize {
        let now = self.st.tasks[self.me].clock;
        let mut woke = 0;
        for _ in 0..n {
            let Some(t) = self.st.futex.get_mut(&addr).and_then(|q| q.pop_front()) else {
                break;
            };
            self.st.tasks[t].wake_at = None;
            self.st.tasks[t].state = TaskState::Ready;
            self.st.tasks[t].clock =
                self.st.tasks[t].clock.max(now + self.cfg.profile.sched_latency_ns);
            let dest = if self.st.tasks[t].pinned {
                self.st.tasks[t].core
            } else {
                // Migrate to the least-loaded core (deterministic tie-break).
                let (dest, _) = self
                    .st
                    .cores
                    .iter()
                    .enumerate()
                    .map(|(i, c)| (i, c.ready.len() + c.current.is_some() as usize))
                    .min_by_key(|&(i, load)| (load, i))
                    .unwrap();
                dest
            };
            if dest != self.st.tasks[t].core {
                self.st.tasks[t].core = dest;
                self.st.stats.migrations += 1;
            }
            self.st.cores[dest].ready.push_back(t);
            woke += 1;
        }
        if self.st.futex.get(&addr).map_or(false, |q| q.is_empty()) {
            self.st.futex.remove(&addr);
        }
        woke
    }

    /// Number of sleepers on `addr` (for the release-side wake decision).
    pub fn futex_waiters(&self, addr: u64) -> usize {
        self.st.futex.get(&addr).map_or(0, |q| q.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::{Atom32, World};
    use crate::sim::SimWorld;
    use std::sync::Arc;

    fn cfg(cores: usize) -> MachineCfg {
        MachineCfg::new(cores, OsProfile::linux_rt(), AffinityMode::PinnedSpread)
    }

    #[test]
    fn empty_machine_runs() {
        let m = Machine::new(cfg(2));
        let stats = m.run(Vec::new());
        assert_eq!(stats.virtual_ns, 0);
    }

    #[test]
    fn single_task_charges_work() {
        let m = Machine::new(cfg(1));
        let stats = m.run_tasks(1, |_| || SimWorld::work(5_000));
        assert!(stats.virtual_ns >= 5_000, "{stats:?}");
    }

    #[test]
    fn parallel_tasks_overlap_in_virtual_time() {
        // Two CPU-bound tasks: on 2 cores the makespan is ~1x the work;
        // on 1 core it is ~2x (plus switches).
        let work = 100_000;
        let m2 = Machine::new(cfg(2));
        let s2 = m2.run_tasks(2, |_| move || SimWorld::work(work));
        let m1 = Machine::new(cfg(1));
        let s1 = m1.run_tasks(2, |_| move || SimWorld::work(work));
        assert!(s2.virtual_ns < s1.virtual_ns, "{s2:?} vs {s1:?}");
        assert!(s1.virtual_ns >= 2 * work);
    }

    #[test]
    fn deterministic_stats() {
        let run = || {
            let m = Machine::new(cfg(4));
            let a = Arc::new(<SimWorld as World>::U32::new(0));
            m.run_tasks(4, |_| {
                let a = a.clone();
                move || {
                    for _ in 0..200 {
                        a.fetch_add(1);
                    }
                }
            })
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn atomic_contention_pingpongs_on_multicore_only() {
        let run = |cores| {
            let m = Machine::new(cfg(cores));
            let a = Arc::new(<SimWorld as World>::U32::new(0));
            m.run_tasks(2, |_| {
                let a = a.clone();
                move || {
                    for _ in 0..500 {
                        a.fetch_add(1);
                    }
                }
            })
        };
        let s1 = run(1);
        let s4 = run(4);
        // On one core the line stays resident; on four it ping-pongs.
        assert!(s4.misses > 10 * s1.misses.max(1), "{s1:?} vs {s4:?}");
    }

    #[test]
    fn futex_roundtrip() {
        let m = Machine::new(cfg(2));
        let flag = Arc::new(<SimWorld as World>::U32::new(0));
        let f2 = flag.clone();
        let h1 = m.spawn(move || {
            // Wait until the flag is set. The condition closure runs inside
            // the monitor: it must use peek(), never a charged op.
            SimWorld::futex_wait_on(0xF00D, || f2.peek() == 0);
            assert_eq!(f2.load(), 1);
        });
        let f3 = flag.clone();
        let h2 = m.spawn(move || {
            SimWorld::work(10_000);
            f3.store(1);
            SimWorld::futex_wake_on(0xF00D, usize::MAX);
        });
        let stats = m.run(vec![h1, h2]);
        assert!(stats.virtual_ns >= 10_000);
    }

    #[test]
    #[should_panic(expected = "simulated deadlock")]
    fn deadlock_detected() {
        let m = Machine::new(cfg(1));
        let h = m.spawn(|| {
            SimWorld::futex_wait_on(0xDEAD, || true); // nobody will wake us
        });
        m.run(vec![h]);
    }

    #[test]
    fn quantum_rotation_lets_spinner_progress() {
        // A spinner and a setter pinned to ONE core: only quantum expiry
        // lets the setter run; the spinner must still terminate.
        let m = Machine::new(MachineCfg::new(
            1,
            OsProfile::linux_rt(),
            AffinityMode::SingleCore,
        ));
        let flag = Arc::new(<SimWorld as World>::U32::new(0));
        let f1 = flag.clone();
        let h1 = m.spawn(move || {
            while f1.load() == 0 {
                SimWorld::spin_hint();
            }
        });
        let f2 = flag.clone();
        let h2 = m.spawn(move || {
            f2.store(1);
        });
        let stats = m.run(vec![h1, h2]);
        assert!(stats.ctx_switches >= 1, "{stats:?}");
    }

    #[test]
    fn bus_serializes_misses() {
        // 4 cores all missing constantly: bus busy time ~ total misses * bus_ns
        // and utilization approaches 1.
        let m = Machine::new(cfg(4));
        let stats = m.run_tasks(4, |i| {
            move || {
                // Each task writes its own distinct lines: all cold misses.
                let base = alloc_region(64 * 300);
                for j in 0..300u64 {
                    SimWorld::touch(base + j * 64, 1, true);
                    let _ = i;
                }
            }
        });
        assert_eq!(stats.misses, 1200);
        assert!(stats.bus_utilization() > 0.8, "{stats:?}");
    }

    #[test]
    fn injected_kill_is_clean_single_task_death() {
        use crate::sim::faults::FaultPlan;
        let m = Machine::new(cfg(2));
        // Task 0 would never terminate on its own; only the planned kill
        // ends it. Task 1 must be unaffected and the run must not abort.
        let h0 = m.spawn(|| loop {
            SimWorld::work(10);
        });
        let h1 = m.spawn(|| SimWorld::work(1_000));
        m.set_faults(FaultPlan::new().kill(0, 50));
        let stats = m.run(vec![h0, h1]);
        assert!(m.task_done(0), "killed task must be finished");
        assert!(m.task_done(1));
        assert!(stats.virtual_ns >= 1_000);
        assert!(m.task_ops(0) >= 50);
    }

    #[test]
    fn injected_stall_advances_virtual_time_deterministically() {
        use crate::sim::faults::FaultPlan;
        let run = || {
            let m = Machine::new(cfg(2));
            let handles = vec![
                m.spawn(|| {
                    for _ in 0..100 {
                        SimWorld::work(10);
                    }
                }),
                m.spawn(|| SimWorld::work(500)),
            ];
            m.set_faults(FaultPlan::new().stall(0, 10, 1_000_000));
            m.run(handles)
        };
        let a = run();
        let b = run();
        assert!(a.virtual_ns >= 1_000_000, "{a:?}");
        assert_eq!(a, b, "faulted runs must stay deterministic");
    }

    #[test]
    fn timed_futex_wait_expires_at_virtual_deadline() {
        let m = Machine::new(cfg(1));
        // Nobody ever wakes this address: without the deadline this is the
        // deadlock-detector case; with it, the wait returns at T+5000.
        let h = m.spawn(|| {
            let t0 = SimWorld::now_ns();
            SimWorld::futex_wait_deadline_on(0x71ED, Some(t0 + 5_000), || true);
            let t1 = SimWorld::now_ns();
            assert!(t1 >= t0 + 5_000, "woke early: {t0}..{t1}");
        });
        let stats = m.run(vec![h]);
        assert!(stats.virtual_ns >= 5_000);
    }

    #[test]
    fn free_affinity_migrates_on_wake() {
        let m = Machine::new(MachineCfg::new(2, OsProfile::linux_rt(), AffinityMode::Free));
        let flag = Arc::new(<SimWorld as World>::U32::new(0));
        let f1 = flag.clone();
        // Three tasks on 2 cores; task 2 blocks then wakes and may migrate.
        let h0 = m.spawn(move || {
            SimWorld::work(200_000);
            f1.store(1);
            SimWorld::futex_wake_on(0xBEEF, usize::MAX);
        });
        let f2 = flag.clone();
        let h1 = m.spawn(move || {
            SimWorld::futex_wait_on(0xBEEF, || f2.peek() == 0);
            SimWorld::work(1_000);
        });
        let h2 = m.spawn(move || SimWorld::work(500_000));
        let stats = m.run(vec![h0, h1, h2]);
        assert!(stats.virtual_ns >= 200_000);
    }
}
