//! Deterministic discrete-event SMP simulator.
//!
//! The paper's evaluation ran on 4-core KVM guests; this host may have any
//! number of cores (the CI machine has one). The simulator reproduces the
//! paper's *causal mechanisms* — lock convoys, cache-line ping-pong,
//! kernel-lock context switches, scheduling quanta, CPU affinity — in
//! virtual time, deterministically, while executing the **real algorithm
//! code** (the same generic implementations that run on real atomics).
//!
//! Execution model (conservative serialization):
//!
//! * Every simulated task runs on its own OS thread, but a global monitor
//!   allows exactly **one** task to execute user code at a time: the task
//!   with the minimal virtual clock among the current core occupants.
//!   Interactions therefore happen in virtual-time order and the whole
//!   run is a deterministic function of the configuration.
//! * Each virtual core has a ready queue, an occupant and a core clock.
//!   Quantum expiry and blocking rotate occupants, charging the OS cost
//!   profile's context-switch price.
//! * A MESI-lite cache-line directory decides hit vs. miss per access;
//!   misses queue FIFO on a single memory bus (the paper's QPN bottleneck
//!   resource), whose busy time yields the utilization statistic.
//! * Kernel locks are futex-style: user-mode fast path, syscall + block on
//!   contention, wake with scheduling latency — all priced by
//!   [`crate::os::OsProfile`].
//!
//! [`SimWorld`] (in [`world`]) implements [`crate::lockfree::mem::World`]
//! on top of this machine via a thread-local task context.

pub mod faults;
mod machine;
pub mod world;

pub use faults::{sweep_kill_points, sweep_stall_points, FaultAction, FaultPlan, OpWindow};
pub use machine::{Machine, MachineCfg, MachineStats, MemCosts};
pub use world::SimWorld;
