//! Micro-benchmarks + ablations of the lock-free structures on the real
//! host (wall-clock), plus the DESIGN.md §6 design-choice ablations on
//! the simulator (virtual time):
//!
//! * NBB insert+read round-trip vs. a Mutex<VecDeque> baseline,
//! * **coherence ablation**: cross-thread SPSC throughput of the
//!   padded + cached-peer-counter NBB vs. an unpadded/uncached replica
//!   of the seed datapath, scalar and batched (the PR-over-PR perf
//!   trajectory gate — `scripts/bench_snapshot.sh` snapshots the
//!   `BENCH_JSON:` line this bench emits),
//! * **MPMC scaling**: 2 producers × M ∈ {1, 2, 4} consumers on the
//!   slot-sequence ring, exactly-once asserted, plus the batched-claim
//!   ratio (the `mpmc_scaling_*` BENCH_JSON row),
//! * **MPMC stealing**: the same 2×M grid on the lane-sharded
//!   work-stealing ring (zero shared-RMW home drains + batch steals),
//!   the sharded-vs-shared ratio at 2×2, and a skewed-consumer
//!   imbalance row (the `mpmc_steal_*` BENCH_JSON row),
//! * occupancy bitmap: empty-queue poll cost of `LockFreeQueue::pop`,
//! * NBW write / read vs. a Mutex<T> state cell,
//! * bit-set alloc/free vs. Mutex<Vec> free list (why the paper switched
//!   from the lock-free list design),
//! * ablation: NBB ring capacity (burst absorption),
//! * ablation: message batch size through the full MCAPI stack (sim),
//! * ablation: Table 1 immediate-retry budget,
//! * ablation: NBW buffer depth vs. reader collision rate.
//!
//! Run with: `cargo bench --bench micro_lockfree`

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use mcapi::harness::{header, time_batched};
use mcapi::lockfree::{
    Backoff, BitSet, ChannelRing, FreeList, MpmcRing, Nbb, Nbw, ReadStatus, RealWorld,
    ShardedRing, STEAL_BATCH,
};
use mcapi::mcapi::queue::{Entry, LockFreeQueue};
use mcapi::mrapi::shmem::{Lease, Partition};

/// The seed's NBB datapath, reconstructed as the ablation baseline: the
/// two counters adjacent (same cache line) and both re-loaded on every
/// operation — no padding, no cached peer counters, no batching. Payload
/// fixed to u64 (what the SPSC driver moves).
struct BaselineNbb {
    update: AtomicU64,
    ack: AtomicU64,
    slots: Box<[UnsafeCell<u64>]>,
    cap: u64,
}

unsafe impl Send for BaselineNbb {}
unsafe impl Sync for BaselineNbb {}

impl BaselineNbb {
    fn new(cap: usize) -> Self {
        BaselineNbb {
            update: AtomicU64::new(0),
            ack: AtomicU64::new(0),
            slots: (0..cap).map(|_| UnsafeCell::new(0)).collect(),
            cap: cap as u64,
        }
    }

    fn insert(&self, v: u64) -> bool {
        let u = self.update.load(Ordering::Acquire);
        let a = self.ack.load(Ordering::Acquire);
        if (u / 2).wrapping_sub(a / 2) >= self.cap {
            return false;
        }
        self.update.store(u + 1, Ordering::Release);
        unsafe { *self.slots[((u / 2) % self.cap) as usize].get() = v };
        self.update.store(u + 2, Ordering::Release);
        true
    }

    fn read(&self) -> Option<u64> {
        let a = self.ack.load(Ordering::Acquire);
        let u = self.update.load(Ordering::Acquire);
        if (u / 2).wrapping_sub(a / 2) == 0 {
            return None;
        }
        self.ack.store(a + 1, Ordering::Release);
        let v = unsafe { *self.slots[((a / 2) % self.cap) as usize].get() };
        self.ack.store(a + 2, Ordering::Release);
        Some(v)
    }
}

const SPSC_N: u64 = 2_000_000;
const SPSC_CAP: usize = 1024;

/// Cross-thread SPSC throughput (msgs/s) of the optimized NBB; `batch`
/// = 1 uses the scalar insert/read path, > 1 the batched path.
fn spsc_nbb_mps(batch: usize) -> f64 {
    let q = Arc::new(Nbb::<u64, RealWorld>::new(SPSC_CAP));
    let t0 = Instant::now();
    let producer = {
        let q = q.clone();
        std::thread::spawn(move || {
            if batch <= 1 {
                for i in 0..SPSC_N {
                    while q.insert(i).is_err() {
                        std::hint::spin_loop();
                    }
                }
            } else {
                let mut next = 0u64;
                while next < SPSC_N {
                    let hi = (next + batch as u64).min(SPSC_N);
                    let mut items: Vec<u64> = (next..hi).collect();
                    while !items.is_empty() {
                        if q.insert_batch(&mut items).is_err() {
                            std::hint::spin_loop();
                        }
                    }
                    next = hi;
                }
            }
        })
    };
    let mut got = 0u64;
    if batch <= 1 {
        while got < SPSC_N {
            match q.read() {
                ReadStatus::Ok(v) => {
                    assert_eq!(v, got, "SPSC FIFO violated");
                    got += 1;
                }
                _ => std::hint::spin_loop(),
            }
        }
    } else {
        let mut out = Vec::with_capacity(batch);
        while got < SPSC_N {
            out.clear();
            if q.read_batch(&mut out, batch).is_ok() {
                for v in &out {
                    assert_eq!(*v, got, "SPSC batch FIFO violated");
                    got += 1;
                }
            } else {
                std::hint::spin_loop();
            }
        }
    }
    producer.join().unwrap();
    SPSC_N as f64 / t0.elapsed().as_secs_f64()
}

/// Cross-thread SPSC throughput (msgs/s) of the seed-replica baseline.
fn spsc_baseline_mps() -> f64 {
    let q = Arc::new(BaselineNbb::new(SPSC_CAP));
    let t0 = Instant::now();
    let producer = {
        let q = q.clone();
        std::thread::spawn(move || {
            for i in 0..SPSC_N {
                while !q.insert(i) {
                    std::hint::spin_loop();
                }
            }
        })
    };
    let mut got = 0u64;
    while got < SPSC_N {
        match q.read() {
            Some(v) => {
                assert_eq!(v, got, "baseline SPSC FIFO violated");
                got += 1;
            }
            None => std::hint::spin_loop(),
        }
    }
    producer.join().unwrap();
    SPSC_N as f64 / t0.elapsed().as_secs_f64()
}

// ---------------------------------------------------------------------------
// Connected-channel fast path: ring vs pool+queue packet SPSC.
// ---------------------------------------------------------------------------

const PKT_N: u64 = 1_000_000;
const PKT_CAP: usize = 1024;
const PKT_SLOT: usize = 64;

fn pkt_payload(i: u64) -> [u8; 24] {
    let mut b = [0u8; 24];
    b[..8].copy_from_slice(&i.to_le_bytes());
    b[8..16].copy_from_slice(&i.wrapping_mul(3).to_le_bytes());
    b[16..24].copy_from_slice(&(!i).to_le_bytes());
    b
}

/// Cross-thread SPSC packet throughput of the connected-channel ring:
/// payload bytes live in the slots (no pool lease, no second structure);
/// `batch > 1` drives the amortized submission path, the consumer reads
/// in place via `recv_with`.
fn spsc_ring_pkt_mps(batch: usize) -> f64 {
    let ring = Arc::new(ChannelRing::<RealWorld>::new(PKT_CAP, PKT_SLOT));
    let t0 = Instant::now();
    let producer = {
        let ring = ring.clone();
        std::thread::spawn(move || {
            if batch <= 1 {
                for i in 0..PKT_N {
                    let b = pkt_payload(i);
                    while ring.send(&b).is_err() {
                        std::hint::spin_loop();
                    }
                }
            } else {
                let mut bufs = vec![[0u8; 24]; batch];
                let mut i = 0u64;
                while i < PKT_N {
                    let k = ((PKT_N - i) as usize).min(batch);
                    for (j, b) in bufs[..k].iter_mut().enumerate() {
                        *b = pkt_payload(i + j as u64);
                    }
                    let mut sent = 0;
                    while sent < k {
                        let refs: Vec<&[u8]> =
                            bufs[sent..k].iter().map(|b| b.as_slice()).collect();
                        match ring.send_batch(&refs) {
                            Ok(n) => sent += n,
                            Err(_) => std::hint::spin_loop(),
                        }
                    }
                    i += k as u64;
                }
            }
        })
    };
    let mut got = 0u64;
    while got < PKT_N {
        let r = ring.recv_with(|b| {
            assert_eq!(b.len(), 24, "ring packet length");
            u64::from_le_bytes(b[..8].try_into().unwrap())
        });
        match r {
            Ok(v) => {
                assert_eq!(v, got, "ring packet FIFO violated");
                got += 1;
            }
            Err(_) => std::hint::spin_loop(),
        }
    }
    producer.join().unwrap();
    PKT_N as f64 / t0.elapsed().as_secs_f64()
}

/// Cross-thread SPSC packet throughput of the generic path the connected
/// channels used before the fast path: pool lease -> payload copy into
/// the pool -> Entry through the MPMC queue -> payload copy out of the
/// pool -> lease release.
fn spsc_queue_pkt_mps() -> f64 {
    let pool = Arc::new(Partition::<RealWorld>::new(PKT_CAP + 64, PKT_SLOT));
    let q = Arc::new(LockFreeQueue::<RealWorld>::new(1, PKT_CAP));
    let t0 = Instant::now();
    let producer = {
        let pool = pool.clone();
        let q = q.clone();
        std::thread::spawn(move || {
            for i in 0..PKT_N {
                let b = pkt_payload(i);
                let lease = loop {
                    if let Some(l) = pool.acquire() {
                        break l;
                    }
                    std::hint::spin_loop();
                };
                pool.write(&lease, &b);
                let mut e = Entry::buffered(lease.index as u32, 24, 0, 0);
                loop {
                    match q.push(e) {
                        Ok(()) => break,
                        Err((_, back)) => {
                            e = back;
                            std::hint::spin_loop();
                        }
                    }
                }
            }
        })
    };
    let mut got = 0u64;
    let mut out = [0u8; 24];
    while got < PKT_N {
        match q.pop() {
            Ok(e) => {
                let lease = Lease {
                    index: e.buf_index as usize,
                    offset: e.buf_index as usize * PKT_SLOT,
                    len: PKT_SLOT,
                };
                pool.read(&lease, &mut out);
                let v = u64::from_le_bytes(out[..8].try_into().unwrap());
                assert_eq!(v, got, "queue packet FIFO violated");
                pool.release(lease);
                got += 1;
            }
            Err(_) => std::hint::spin_loop(),
        }
    }
    producer.join().unwrap();
    PKT_N as f64 / t0.elapsed().as_secs_f64()
}

// ---------------------------------------------------------------------------
// MPMC endpoint plane: consumer-group scaling on the slot-sequence ring.
// ---------------------------------------------------------------------------

const MPMC_N: u64 = 200_000;
const MPMC_CAP: usize = 1024;

/// Cross-thread MPMC throughput (msgs/s): `producers` senders fan
/// 8-byte sequence frames into one slot-sequence ring, `consumers`
/// claimants drain it concurrently. Exactly-once is asserted with a
/// count + checksum pair (each sequence claimed by exactly one
/// consumer). `batch > 1` drives the amortized multi-slot claim.
fn mpmc_ring_mps(producers: usize, consumers: usize, batch: usize) -> f64 {
    let ring = Arc::new(MpmcRing::<RealWorld>::new(MPMC_CAP, 16));
    let done = Arc::new(AtomicU64::new(0));
    let sum = Arc::new(AtomicU64::new(0));
    let per = MPMC_N / producers as u64;
    let total = per * producers as u64;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let ring = ring.clone();
        handles.push(std::thread::spawn(move || {
            let who = p as u32;
            let base = p as u64 * per;
            if batch <= 1 {
                for i in 0..per {
                    let b = (base + i).to_le_bytes();
                    while ring.send(who, &b).is_err() {
                        std::thread::yield_now();
                    }
                }
            } else {
                let mut i = 0u64;
                while i < per {
                    let k = ((per - i) as usize).min(batch);
                    let bufs: Vec<[u8; 8]> =
                        (0..k).map(|j| (base + i + j as u64).to_le_bytes()).collect();
                    let mut sent = 0usize;
                    while sent < k {
                        let refs: Vec<&[u8]> =
                            bufs[sent..k].iter().map(|b| b.as_slice()).collect();
                        match ring.send_batch(who, &refs) {
                            Ok(n) => sent += n,
                            Err(_) => std::thread::yield_now(),
                        }
                    }
                    i += k as u64;
                }
            }
        }));
    }
    for c in 0..consumers {
        let ring = ring.clone();
        let (done, sum) = (done.clone(), sum.clone());
        handles.push(std::thread::spawn(move || {
            let who = (producers + c) as u32;
            loop {
                match ring.recv_with(who, |b| u64::from_le_bytes(b[..8].try_into().unwrap())) {
                    Ok(v) => {
                        sum.fetch_add(v, Ordering::Relaxed);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        if done.load(Ordering::Relaxed) >= total {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(done.load(Ordering::SeqCst), total, "MPMC lost or duplicated a frame");
    assert_eq!(
        sum.load(Ordering::SeqCst),
        total * (total - 1) / 2,
        "MPMC sequence checksum mismatch (duplicate + loss cancelled out)"
    );
    total as f64 / t0.elapsed().as_secs_f64()
}

/// Cross-thread MPMC throughput (msgs/s) of the lane-sharded
/// work-stealing ring: `producers` senders publish 8-byte sequence
/// frames on their own SPSC lane, `consumers` attach as group members
/// and drain home lanes (zero shared RMWs in steady state), batch
/// stealing when dry. Exactly-once asserted with the same
/// count + checksum pair as the shared-ring run. `slow_factor` injects
/// that many yields before each of consumer 0's receive attempts — the
/// imbalance row: its peers must absorb the backlog by stealing.
fn mpmc_steal_mps(producers: usize, consumers: usize, slow_factor: usize) -> f64 {
    let ring =
        Arc::new(ShardedRing::<RealWorld>::new(producers, producers + consumers, MPMC_CAP, 16));
    let done = Arc::new(AtomicU64::new(0));
    let sum = Arc::new(AtomicU64::new(0));
    let per = MPMC_N / producers as u64;
    let total = per * producers as u64;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let ring = ring.clone();
        handles.push(std::thread::spawn(move || {
            let lane = p as u32;
            let base = p as u64 * per;
            for i in 0..per {
                let b = (base + i).to_le_bytes();
                while ring.send(lane, &b).is_err() {
                    std::thread::yield_now();
                }
            }
        }));
    }
    for c in 0..consumers {
        let ring = ring.clone();
        let (done, sum) = (done.clone(), sum.clone());
        handles.push(std::thread::spawn(move || {
            let who = (producers + c) as u32;
            ring.attach_member(who);
            loop {
                if c == 0 {
                    for _ in 0..slow_factor {
                        std::thread::yield_now();
                    }
                }
                match ring.recv_as(who, |b| u64::from_le_bytes(b[..8].try_into().unwrap())) {
                    Ok(v) => {
                        sum.fetch_add(v, Ordering::Relaxed);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        if done.load(Ordering::Relaxed) >= total {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(done.load(Ordering::SeqCst), total, "sharded MPMC lost or duplicated a frame");
    assert_eq!(
        sum.load(Ordering::SeqCst),
        total * (total - 1) / 2,
        "sharded MPMC sequence checksum mismatch (duplicate + loss cancelled out)"
    );
    total as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("{}", header());

    // --- NBB vs mutex deque (uncontended round-trip) -----------------------
    let nbb = Nbb::<u64, RealWorld>::new(64);
    let s = time_batched("nbb insert+read", 2, 50, 10_000, |i| {
        nbb.insert(i).map_err(|_| ()).unwrap();
        matches!(nbb.read(), ReadStatus::Ok(_))
    });
    println!("{}", s.row());
    let nbb_ns = s.mean_ns;

    let deque = Mutex::new(VecDeque::<u64>::with_capacity(64));
    let s = time_batched("mutex deque push+pop", 2, 50, 10_000, |i| {
        deque.lock().unwrap().push_back(i);
        deque.lock().unwrap().pop_front()
    });
    println!("{}", s.row());

    // --- coherence ablation: padded+cached vs seed-replica SPSC -------------
    println!("\ncoherence ablation: cross-thread SPSC throughput ({SPSC_N} msgs, cap {SPSC_CAP})");
    println!("| variant | throughput (Mmsg/s) |");
    println!("|---|---|");
    let base_mps = spsc_baseline_mps();
    println!("| unpadded + uncached (seed replica) | {:.2} |", base_mps / 1e6);
    let nbb_mps = spsc_nbb_mps(1);
    println!("| padded + cached counters | {:.2} |", nbb_mps / 1e6);
    let nbb_batch_mps = spsc_nbb_mps(32);
    println!("| padded + cached + batch 32 | {:.2} |", nbb_batch_mps / 1e6);
    let spsc_ratio = nbb_mps / base_mps;
    let batch_ratio = nbb_batch_mps / base_mps;
    println!(
        "padded+cached vs baseline: {spsc_ratio:.2}x | with batching: {batch_ratio:.2}x \
         (single-core hosts flatten the gap: the win is cross-core line traffic)"
    );

    // --- connected-channel fast path: ring vs pool+queue packets -------------
    println!("\nconnected-channel ablation: SPSC packet path ({PKT_N} pkts of 24 B, cap {PKT_CAP})");
    println!("| variant | throughput (Mpkt/s) |");
    println!("|---|---|");
    let queue_pkt_mps = spsc_queue_pkt_mps();
    println!("| pool lease + generic queue (pre-fast-path) | {:.2} |", queue_pkt_mps / 1e6);
    let ring_pkt_mps = spsc_ring_pkt_mps(1);
    println!("| channel ring (payload in slot) | {:.2} |", ring_pkt_mps / 1e6);
    let ring_pkt_batch_mps = spsc_ring_pkt_mps(32);
    println!("| channel ring + batch 32 | {:.2} |", ring_pkt_batch_mps / 1e6);
    let pkt_ring_ratio = ring_pkt_mps / queue_pkt_mps;
    let pkt_ring_batch_ratio = ring_pkt_batch_mps / queue_pkt_mps;
    println!(
        "ring vs pool+queue: {pkt_ring_ratio:.2}x | with batching: {pkt_ring_batch_ratio:.2}x \
         (the ring drops the Treiber lease pop/push and one payload hop per packet)"
    );

    // --- MPMC endpoint plane: consumer-group scaling -------------------------
    println!(
        "\nmpmc scaling: 2 producers x M consumers on the slot-sequence ring \
         ({MPMC_N} msgs, cap {MPMC_CAP})"
    );
    println!("| consumers | throughput (Mmsg/s) |");
    println!("|---|---|");
    let mpmc_c1_mps = mpmc_ring_mps(2, 1, 1);
    println!("| 1 | {:.2} |", mpmc_c1_mps / 1e6);
    let mpmc_c2_mps = mpmc_ring_mps(2, 2, 1);
    println!("| 2 | {:.2} |", mpmc_c2_mps / 1e6);
    let mpmc_c4_mps = mpmc_ring_mps(2, 4, 1);
    println!("| 4 | {:.2} |", mpmc_c4_mps / 1e6);
    let mpmc_batch_mps = mpmc_ring_mps(2, 2, 32);
    let mpmc_batch_ratio = mpmc_batch_mps / mpmc_c2_mps;
    println!(
        "mpmc batch-32 producers at 2 consumers: {:.2} Mmsg/s = {mpmc_batch_ratio:.2}x scalar \
         (scaling with M needs >= 4 free cores; CI runners only gate > 0 and exactly-once)",
        mpmc_batch_mps / 1e6
    );

    // --- MPMC stealing: lane-sharded rings vs the shared-CAS ring ------------
    println!(
        "\nmpmc steal: 2 producers x M consumers on lane-sharded rings \
         ({MPMC_N} msgs, cap {MPMC_CAP}, steal batch {STEAL_BATCH})"
    );
    println!("| consumers | throughput (Mmsg/s) |");
    println!("|---|---|");
    let steal_c1_mps = mpmc_steal_mps(2, 1, 0);
    println!("| 1 | {:.2} |", steal_c1_mps / 1e6);
    let steal_c2_mps = mpmc_steal_mps(2, 2, 0);
    println!("| 2 | {:.2} |", steal_c2_mps / 1e6);
    let steal_c4_mps = mpmc_steal_mps(2, 4, 0);
    println!("| 4 | {:.2} |", steal_c4_mps / 1e6);
    let steal_vs_shared = steal_c2_mps / mpmc_c2_mps;
    let steal_skew_mps = mpmc_steal_mps(2, 2, 16);
    println!(
        "sharded-vs-shared at 2x2: {steal_vs_shared:.2}x | skewed consumer (16 yields/poll): \
         {:.2} Mmsg/s (peers steal the slow member's backlog; exactly-once still asserted)",
        steal_skew_mps / 1e6
    );

    // --- occupancy bitmap: empty-queue poll cost -----------------------------
    let q = LockFreeQueue::<RealWorld>::new(8, 16);
    let s = time_batched("lfqueue empty pop (8 producers)", 2, 50, 10_000, |_| q.pop());
    println!("{}", s.row());
    let empty_pop_ns = s.mean_ns;
    // Sanity: the bitmap keeps the poll O(priorities), and a drained lane
    // does not linger as a flagged lane.
    q.push(Entry::scalar(1, 3)).unwrap();
    assert_eq!(q.pop().unwrap().scalar, 1);
    assert!(q.pop().is_err());

    // --- NBW vs mutex state cell -------------------------------------------
    let nbw = Nbw::<[u64; 4], RealWorld>::new(4, [0; 4]);
    let s = time_batched("nbw write", 2, 50, 10_000, |i| nbw.write([i, i, i, i]));
    println!("{}", s.row());
    let s = time_batched("nbw read", 2, 50, 10_000, |_| nbw.read().0);
    println!("{}", s.row());
    let cell = Mutex::new([0u64; 4]);
    let s = time_batched("mutex state write", 2, 50, 10_000, |i| {
        *cell.lock().unwrap() = [i, i, i, i];
    });
    println!("{}", s.row());

    // --- bit set vs mutex free list ------------------------------------------
    let bits = BitSet::<RealWorld>::new(256);
    let s = time_batched("bitset alloc+free", 2, 50, 10_000, |_| {
        let i = bits.alloc().unwrap();
        bits.free(i)
    });
    println!("{}", s.row());
    let flist = Mutex::new((0..256usize).collect::<Vec<_>>());
    let s = time_batched("mutex freelist pop+push", 2, 50, 10_000, |_| {
        let i = flist.lock().unwrap().pop().unwrap();
        flist.lock().unwrap().push(i);
    });
    println!("{}", s.row());
    let tre = FreeList::<RealWorld>::new_full(256);
    let s = time_batched("treiber pop+push", 2, 50, 10_000, |_| {
        let i = tre.pop().unwrap();
        tre.push(i);
    });
    println!("{}", s.row());

    // --- ablation: NBB capacity (burst absorption, sim virtual time) --------
    println!("\nablation: NBB ring capacity (sim, linux 4c, 400 tx message stress)");
    println!("| capacity | throughput (kmsg/s) | sender yields |");
    println!("|---|---|---|");
    for cap in [1usize, 4, 16, 64] {
        let machine = mcapi::sim::Machine::new(mcapi::sim::MachineCfg::new(
            4,
            mcapi::os::OsProfile::linux_rt(),
            mcapi::os::AffinityMode::PinnedSpread,
        ));
        let cfg = mcapi::mcapi::types::RuntimeCfg {
            nbb_capacity: cap,
            ..mcapi::mcapi::types::RuntimeCfg::default()
        };
        let topo = mcapi::coordinator::Topology::one_way(
            mcapi::coordinator::MsgKind::Message,
            400,
        );
        let r = mcapi::coordinator::run_stress_sim(
            &machine,
            cfg,
            &topo,
            mcapi::coordinator::StressOpts::default(),
        );
        println!("| {} | {:.1} | {} |", cap, r.kmsgs_per_s(), r.yields);
    }

    // --- ablation: message batch size through the full stack (sim) ----------
    println!("\nablation: msg_send_batch/msg_recv_batch size (sim, linux 2c, 400 tx messages)");
    println!("| batch | throughput (kmsg/s) | line accesses | virtual ns |");
    println!("|---|---|---|---|");
    for batch in [1usize, 4, 16, 64] {
        let machine = mcapi::sim::Machine::new(mcapi::sim::MachineCfg::new(
            2,
            mcapi::os::OsProfile::linux_rt(),
            mcapi::os::AffinityMode::PinnedSpread,
        ));
        let topo = mcapi::coordinator::Topology::one_way(
            mcapi::coordinator::MsgKind::Message,
            400,
        );
        let r = mcapi::coordinator::run_stress_sim(
            &machine,
            mcapi::mcapi::types::RuntimeCfg::default(),
            &topo,
            mcapi::coordinator::StressOpts::with_batch(batch),
        );
        let sim = r.sim.unwrap();
        println!(
            "| {} | {:.1} | {} | {} |",
            batch,
            r.kmsgs_per_s(),
            sim.hits + sim.misses,
            r.elapsed_ns
        );
    }

    // --- ablation: immediate-retry budget (Table 1 semantics) ----------------
    println!("\nablation: Table 1 immediate-retry budget (spin vs yield mix)");
    println!("| budget | retries consumed before yield |");
    println!("|---|---|");
    for limit in [0u32, 2, 8, 32] {
        let mut b = Backoff::<RealWorld>::with_limit(limit);
        let mut spins = 0;
        while b.immediate() {
            spins += 1;
        }
        println!("| {limit} | {spins} |");
        assert_eq!(spins, limit);
    }

    // --- ablation: NBW depth vs reader retries under a fast writer -----------
    println!("\nablation: NBW buffer depth vs reader collision rate (2 threads, host)");
    println!("| depth | reads | collisions | collision rate |");
    println!("|---|---|---|---|");
    for depth in [1usize, 2, 4, 8] {
        let nbw = std::sync::Arc::new(Nbw::<[u64; 4], RealWorld>::new(depth, [0; 4]));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let w = {
            let nbw = nbw.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    i += 1;
                    nbw.write([i, i, i, i]);
                }
            })
        };
        let mut collisions = 0u64;
        const READS: u64 = 200_000;
        for _ in 0..READS {
            let (_, retries) = nbw.read();
            collisions += retries as u64;
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        w.join().unwrap();
        println!(
            "| {depth} | {READS} | {collisions} | {:.4}% |",
            collisions as f64 / READS as f64 * 100.0
        );
    }

    // NBB round-trip must stay fast (perf gate, see EXPERIMENTS.md §Perf).
    assert!(nbb_ns < 250.0, "NBB round-trip regressed: {nbb_ns:.0} ns");
    // The optimized SPSC path must never fall meaningfully behind the
    // seed replica (a hard floor; the expected multi-core win is recorded
    // by scripts/bench_snapshot.sh in BENCH_micro.json per machine).
    assert!(
        spsc_ratio > 0.7,
        "padded+cached NBB slower than the seed replica: {spsc_ratio:.2}x"
    );
    // The connected-channel ring must never fall meaningfully behind the
    // pool+queue path it replaces — it strictly removes work per packet
    // (same floor discipline as the NBB gate above).
    assert!(
        pkt_ring_ratio > 0.7,
        "channel ring slower than the pool+queue packet path: {pkt_ring_ratio:.2}x"
    );

    // Machine-readable snapshot for the perf trajectory
    // (scripts/bench_snapshot.sh merges every BENCH_JSON line into
    // BENCH_micro.json).
    println!(
        "\nBENCH_JSON: {{\"nbb_roundtrip_ns\": {:.1}, \"spsc_baseline_mps\": {:.0}, \
         \"spsc_padded_cached_mps\": {:.0}, \"spsc_batch32_mps\": {:.0}, \
         \"spsc_ratio\": {:.3}, \"spsc_batch_ratio\": {:.3}, \"empty_pop_ns\": {:.1}}}",
        nbb_ns, base_mps, nbb_mps, nbb_batch_mps, spsc_ratio, batch_ratio, empty_pop_ns
    );
    println!(
        "BENCH_JSON: {{\"pkt_queue_mps\": {:.0}, \"pkt_ring_mps\": {:.0}, \
         \"pkt_ring_batch32_mps\": {:.0}, \"pkt_ring_vs_queue\": {:.3}, \
         \"pkt_ring_batch_vs_queue\": {:.3}}}",
        queue_pkt_mps, ring_pkt_mps, ring_pkt_batch_mps, pkt_ring_ratio, pkt_ring_batch_ratio
    );
    // MPMC scaling row: absolute throughputs per consumer count plus the
    // batched-claim ratio. No cross-count assertion here — scaling with M
    // is machine-dependent (needs >= 4 free cores); the exactly-once
    // count+checksum asserts inside mpmc_ring_mps are the hard gate.
    assert!(
        mpmc_c1_mps > 0.0 && mpmc_c2_mps > 0.0 && mpmc_c4_mps > 0.0 && mpmc_batch_mps > 0.0,
        "MPMC scaling run produced a zero throughput"
    );
    println!(
        "BENCH_JSON: {{\"mpmc_scaling_c1_mps\": {:.0}, \"mpmc_scaling_c2_mps\": {:.0}, \
         \"mpmc_scaling_c4_mps\": {:.0}, \"mpmc_scaling_batch_ratio\": {:.3}}}",
        mpmc_c1_mps, mpmc_c2_mps, mpmc_c4_mps, mpmc_batch_ratio
    );
    // Work-stealing row: the sharded grid, the 2x2 sharded-vs-shared
    // ratio, and the skewed-consumer row. Same discipline as the shared
    // ring — absolute numbers are machine-dependent, exactly-once
    // inside mpmc_steal_mps is the hard gate, > 0 the sanity floor.
    assert!(
        steal_c1_mps > 0.0
            && steal_c2_mps > 0.0
            && steal_c4_mps > 0.0
            && steal_skew_mps > 0.0,
        "MPMC steal run produced a zero throughput"
    );
    println!(
        "BENCH_JSON: {{\"mpmc_steal_c1_mps\": {:.0}, \"mpmc_steal_c2_mps\": {:.0}, \
         \"mpmc_steal_c4_mps\": {:.0}, \"mpmc_steal_vs_shared\": {:.3}, \
         \"mpmc_steal_skew_mps\": {:.0}}}",
        steal_c1_mps, steal_c2_mps, steal_c4_mps, steal_vs_shared, steal_skew_mps
    );
    // Robustness counters from one steady packet stress run. All three
    // must stay zero on the healthy path (the chaos suite exercises the
    // non-zero cases); snapshotting them catches silent regressions —
    // e.g. a watchdog misfire reclaiming live leases.
    {
        let machine = mcapi::sim::Machine::new(mcapi::sim::MachineCfg::new(
            4,
            mcapi::os::OsProfile::linux_rt(),
            mcapi::os::AffinityMode::PinnedSpread,
        ));
        let topo =
            mcapi::coordinator::Topology::one_way(mcapi::coordinator::MsgKind::Packet, 400);
        let r = mcapi::coordinator::run_stress_sim(
            &machine,
            mcapi::mcapi::types::RuntimeCfg::default(),
            &topo,
            mcapi::coordinator::StressOpts::default(),
        );
        assert_eq!(
            (r.timeouts, r.poisons, r.leases_reclaimed),
            (0, 0, 0),
            "steady stress must not trip robustness counters"
        );
        println!(
            "BENCH_JSON: {{\"stress_pkt_timeouts\": {}, \"stress_pkt_poisons\": {}, \
             \"stress_pkt_leases_reclaimed\": {}, \"stress_pkt_latency_p999_ns\": {}}}",
            r.timeouts,
            r.poisons,
            r.leases_reclaimed,
            r.latency.p999()
        );
    }
    println!("micro_lockfree OK");
}
