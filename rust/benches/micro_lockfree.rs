//! Micro-benchmarks + ablations of the lock-free structures on the real
//! host (wall-clock), plus the DESIGN.md §6 design-choice ablations on
//! the simulator (virtual time):
//!
//! * NBB insert+read round-trip vs. a Mutex<VecDeque> baseline,
//! * NBW write / read vs. a Mutex<T> state cell,
//! * bit-set alloc/free vs. Mutex<Vec> free list (why the paper switched
//!   from the lock-free list design),
//! * ablation: NBB ring capacity (burst absorption),
//! * ablation: Table 1 immediate-retry budget,
//! * ablation: NBW buffer depth vs. reader collision rate.
//!
//! Run with: `cargo bench --bench micro_lockfree`

use std::collections::VecDeque;
use std::sync::Mutex;

use mcapi::harness::{header, time_batched};
use mcapi::lockfree::{Backoff, BitSet, FreeList, Nbb, Nbw, ReadStatus, RealWorld};

fn main() {
    println!("{}", header());

    // --- NBB vs mutex deque (uncontended round-trip) -----------------------
    let nbb = Nbb::<u64, RealWorld>::new(64);
    let s = time_batched("nbb insert+read", 2, 50, 10_000, |i| {
        nbb.insert(i).map_err(|_| ()).unwrap();
        matches!(nbb.read(), ReadStatus::Ok(_))
    });
    println!("{}", s.row());
    let nbb_ns = s.mean_ns;

    let deque = Mutex::new(VecDeque::<u64>::with_capacity(64));
    let s = time_batched("mutex deque push+pop", 2, 50, 10_000, |i| {
        deque.lock().unwrap().push_back(i);
        deque.lock().unwrap().pop_front()
    });
    println!("{}", s.row());

    // --- NBW vs mutex state cell -------------------------------------------
    let nbw = Nbw::<[u64; 4], RealWorld>::new(4, [0; 4]);
    let s = time_batched("nbw write", 2, 50, 10_000, |i| nbw.write([i, i, i, i]));
    println!("{}", s.row());
    let s = time_batched("nbw read", 2, 50, 10_000, |_| nbw.read().0);
    println!("{}", s.row());
    let cell = Mutex::new([0u64; 4]);
    let s = time_batched("mutex state write", 2, 50, 10_000, |i| {
        *cell.lock().unwrap() = [i, i, i, i];
    });
    println!("{}", s.row());

    // --- bit set vs mutex free list ------------------------------------------
    let bits = BitSet::<RealWorld>::new(256);
    let s = time_batched("bitset alloc+free", 2, 50, 10_000, |_| {
        let i = bits.alloc().unwrap();
        bits.free(i)
    });
    println!("{}", s.row());
    let flist = Mutex::new((0..256usize).collect::<Vec<_>>());
    let s = time_batched("mutex freelist pop+push", 2, 50, 10_000, |_| {
        let i = flist.lock().unwrap().pop().unwrap();
        flist.lock().unwrap().push(i);
    });
    println!("{}", s.row());
    let tre = FreeList::<RealWorld>::new_full(256);
    let s = time_batched("treiber pop+push", 2, 50, 10_000, |_| {
        let i = tre.pop().unwrap();
        tre.push(i);
    });
    println!("{}", s.row());

    // --- ablation: NBB capacity (burst absorption, sim virtual time) --------
    println!("\nablation: NBB ring capacity (sim, linux 4c, 400 tx message stress)");
    println!("| capacity | throughput (kmsg/s) | sender yields |");
    println!("|---|---|---|");
    for cap in [1usize, 4, 16, 64] {
        let machine = mcapi::sim::Machine::new(mcapi::sim::MachineCfg::new(
            4,
            mcapi::os::OsProfile::linux_rt(),
            mcapi::os::AffinityMode::PinnedSpread,
        ));
        let cfg = mcapi::mcapi::types::RuntimeCfg {
            nbb_capacity: cap,
            ..mcapi::mcapi::types::RuntimeCfg::default()
        };
        let topo = mcapi::coordinator::Topology::one_way(
            mcapi::coordinator::MsgKind::Message,
            400,
        );
        let r = mcapi::coordinator::run_stress_sim(
            &machine,
            cfg,
            &topo,
            mcapi::coordinator::StressOpts::default(),
        );
        println!("| {} | {:.1} | {} |", cap, r.kmsgs_per_s(), r.yields);
    }

    // --- ablation: immediate-retry budget (Table 1 semantics) ----------------
    println!("\nablation: Table 1 immediate-retry budget (spin vs yield mix)");
    println!("| budget | retries consumed before yield |");
    println!("|---|---|");
    for limit in [0u32, 2, 8, 32] {
        let mut b = Backoff::<RealWorld>::with_limit(limit);
        let mut spins = 0;
        while b.immediate() {
            spins += 1;
        }
        println!("| {limit} | {spins} |");
        assert_eq!(spins, limit);
    }

    // --- ablation: NBW depth vs reader retries under a fast writer -----------
    println!("\nablation: NBW buffer depth vs reader collision rate (2 threads, host)");
    println!("| depth | reads | collisions | collision rate |");
    println!("|---|---|---|---|");
    for depth in [1usize, 2, 4, 8] {
        let nbw = std::sync::Arc::new(Nbw::<[u64; 4], RealWorld>::new(depth, [0; 4]));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let w = {
            let nbw = nbw.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    i += 1;
                    nbw.write([i, i, i, i]);
                }
            })
        };
        let mut collisions = 0u64;
        const READS: u64 = 200_000;
        for _ in 0..READS {
            let (_, retries) = nbw.read();
            collisions += retries as u64;
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        w.join().unwrap();
        println!(
            "| {depth} | {READS} | {collisions} | {:.4}% |",
            collisions as f64 / READS as f64 * 100.0
        );
    }

    // NBB round-trip must stay fast (perf gate, see EXPERIMENTS.md §Perf).
    assert!(nbb_ns < 250.0, "NBB round-trip regressed: {nbb_ns:.0} ns");
    println!("\nmicro_lockfree OK");
}
