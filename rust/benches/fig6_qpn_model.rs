//! Bench: regenerate **Figure 6** — QPN model simulation results (memory
//! bus utilization + throughput % of target vs. cache hit rate, 1 vs 2
//! cores), through all three solvers:
//!
//! * AOT MVA artifact (Pallas `mva_kernel` via PJRT),
//! * AOT discrete-time sweep artifact (Pallas `qpn_step` in a scan),
//! * native Rust MVA (cross-check).
//!
//! Also times the PJRT execution itself (the artifact is one fused XLA
//! call over the whole 256-lane grid).
//!
//! Run with: `make artifacts && cargo bench --bench fig6_qpn_model`

use mcapi::model::{analytic, QpnModel, Workload};
use mcapi::runtime::PjrtRuntime;

fn main() {
    let t0 = std::time::Instant::now();
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = QpnModel::load(&rt).expect("run `make artifacts` first");
    let w = Workload::message();
    let hits = QpnModel::default_hits();

    println!("Figure 6 — QPN model (message workload)\n");
    println!("| h | cores | util (mva) | %target (mva) | util (sweep) | %target (sweep) |");
    println!("|---|---|---|---|---|---|");
    let mva = model.fig6_mva(&w, &[1, 2], &hits).expect("mva artifact");
    let sweep = model.fig6_sweep(&w, &[1, 2], &hits).expect("sweep artifact");
    for (m, s) in mva.iter().zip(&sweep) {
        println!(
            "| {:.2} | {} | {:.3} | {:.1}% | {:.3} | {:.1}% |",
            m.hit_rate,
            m.cores,
            m.utilization,
            m.target_fraction * 100.0,
            s.utilization,
            s.target_fraction * 100.0
        );
    }

    // Shape gates (the paper's reading of Figure 6):
    // single core cannot reach the target even at h=1.
    let single_last = &mva[hits.len() - 1];
    assert!(single_last.cores == 1 && single_last.target_fraction < 1.0);
    assert!(single_last.target_fraction > 0.85, "but close at h=1");
    // two cores raise utilization at equal h and approach the target.
    for i in 0..hits.len() {
        assert!(mva[hits.len() + i].utilization >= mva[i].utilization - 1e-3);
    }
    assert!(mva[2 * hits.len() - 1].target_fraction > single_last.target_fraction);
    // native cross-check
    for p in &mva {
        let scaled = Workload { z: w.z * p.cores as f64, ..w };
        let native = analytic::mva(&scaled, p.hit_rate, p.cores);
        assert!((p.throughput - native.throughput).abs() / native.throughput < 1e-3);
    }

    // Timing: per-call latency of each artifact over the full grid.
    for (name, f) in [
        ("mva artifact (256 lanes)", true),
        ("sweep artifact (256 lanes, 32k ns)", false),
    ] {
        let stats = mcapi::harness::time_fn(name, 2, if f { 20 } else { 5 }, |_| {
            if f {
                model.fig6_mva(&w, &[1, 2], &hits).unwrap()
            } else {
                model.fig6_sweep(&w, &[1, 2], &hits).unwrap()
            }
        });
        println!("\n{}", mcapi::harness::header());
        println!("{}", stats.row());
    }
    println!("\nharness wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
