//! Bench: regenerate **Table 2** — lock-based MCAPI multicore penalty.
//!
//! Deterministic simulator workload (virtual time), so a single run per
//! cell is exact; wall-clock of the harness itself is reported for
//! reference. Paper targets: Windows 0.67–0.80x, Linux 0.21–0.24x.
//!
//! Run with: `cargo bench --bench table2_multicore_penalty`

use mcapi::coordinator::experiment::{print_table2, Matrix};

fn main() {
    let t0 = std::time::Instant::now();
    let matrix = Matrix::new(1000);
    let rows = matrix.table2();
    println!("Table 2 — lock-based MCAPI multicore penalty (throughput speedup, eq. 6-1)\n");
    println!("{}", print_table2(&rows));
    println!("paper reference:");
    println!("| windows | message/packet/scalar | 0.74x / 0.67x / 0.80x | 0.74x / 0.68x / 0.69x |");
    println!("| linux   | message/packet/scalar | 0.23x / 0.22x / 0.24x | 0.22x / 0.21x / 0.22x |");
    // Shape gates (CI-checked here, mirrored in rust/tests/).
    for (os, kind, task, aff) in &rows {
        assert!(*task < 1.0 && *aff < 1.0, "{os}/{kind}: penalty must be < 1");
    }
    let mean = |os: &str| {
        let v: Vec<f64> = rows.iter().filter(|r| r.0 == os).map(|r| r.2).collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    assert!(mean("linux") < 0.35, "linux penalty band");
    assert!(mean("windows") > 0.40, "windows penalty band");
    println!("\nharness wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
