//! Bench: regenerate **Figure 7** — MCAPI data exchange throughput for
//! the full test matrix (OS × cores × type × backend × affinity).
//!
//! Run with: `cargo bench --bench fig7_throughput`

use mcapi::coordinator::experiment::{print_fig7, Matrix};
use mcapi::mcapi::types::BackendKind;

fn main() {
    let t0 = std::time::Instant::now();
    let matrix = Matrix::new(1000);
    let cells = matrix.fig7();
    println!("Figure 7 — MCAPI data exchange throughput performance (kmsg/s)\n");
    println!("{}", print_fig7(&cells));

    // Shape gates from the paper's reading of the figure:
    // 1. lock-free beats lock-based in every configuration;
    // 2. lock-based single core beats lock-based multicore (Table 2);
    // 3. lock-based Linux single-core beats Windows single-core (rt futex
    //    fast path vs dispatcher).
    let x = |pred: &dyn Fn(&mcapi::coordinator::experiment::CellResult) -> bool| {
        cells.iter().filter(|c| pred(c)).map(|c| c.kmsgs_per_s()).collect::<Vec<_>>()
    };
    for c in &cells {
        if c.cell.backend == BackendKind::Locked {
            let twin = cells
                .iter()
                .find(|o| {
                    o.cell.backend == BackendKind::LockFree
                        && o.cell.os.name == c.cell.os.name
                        && o.cell.cores == c.cell.cores
                        && o.cell.kind == c.cell.kind
                        && o.cell.affinity == c.cell.affinity
                })
                .unwrap();
            assert!(
                twin.kmsgs_per_s() > c.kmsgs_per_s(),
                "lock-free must beat lock-based: {}",
                c.cell.id()
            );
        }
    }
    let linux_single_locked = x(&|c| {
        c.cell.os.name == "linux" && c.cell.cores == 1 && c.cell.backend == BackendKind::Locked
    });
    let win_single_locked = x(&|c| {
        c.cell.os.name == "windows" && c.cell.cores == 1 && c.cell.backend == BackendKind::Locked
    });
    assert!(
        linux_single_locked.iter().sum::<f64>() > win_single_locked.iter().sum::<f64>(),
        "Linux rt single-core locked must be faster than Windows"
    );
    println!("harness wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
