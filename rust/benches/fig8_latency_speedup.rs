//! Bench: regenerate **Figure 8** — lock-free latency speedup bubbles
//! (eq. 6-2: original latency / test latency), bubble position = the
//! lock-free throughput. Paper: smallest bubble ~2x (single core),
//! largest ~25x (multicore).
//!
//! Run with: `cargo bench --bench fig8_latency_speedup`

use mcapi::coordinator::experiment::{print_fig8, Matrix};

fn main() {
    let t0 = std::time::Instant::now();
    let matrix = Matrix::new(600);
    let rows = matrix.fig8();
    println!("Figure 8 — lock-free MCAPI speedup\n");
    println!("{}", print_fig8(&rows));

    let single: Vec<f64> = rows.iter().filter(|r| r.0.contains("/1c/")).map(|r| r.2).collect();
    let multi: Vec<f64> = rows.iter().filter(|r| !r.0.contains("/1c/")).map(|r| r.2).collect();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let max = multi.iter().fold(0.0f64, |a, &b| a.max(b));
    println!(
        "single-core mean {:.1}x | multicore mean {:.1}x | max {:.1}x (paper: ~2x .. 25x)",
        mean(&single),
        mean(&multi),
        max
    );
    assert!(mean(&single) < mean(&multi), "multicore payoff must dominate");
    assert!(max > 10.0, "double-digit max speedup expected");
    assert!(rows.iter().all(|r| r.2 > 0.9), "lock-free never loses");
    println!("harness wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
