#!/usr/bin/env bash
# Run the micro_lockfree bench and snapshot its machine-readable summary
# (the BENCH_JSON line) into a JSON baseline for the perf trajectory.
#
# Usage: scripts/bench_snapshot.sh [output.json]   (default: BENCH_micro.json
# at the repo root). The full human-readable bench report streams to stdout.
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out="${1:-$repo_root/BENCH_micro.json}"
case "$out" in
  /*) ;;
  *) out="$PWD/$out" ;;
esac

log="$(mktemp)"
trap 'rm -f "$log"' EXIT

(cd "$repo_root/rust" && cargo bench --bench micro_lockfree) | tee "$log"

json_line="$(grep '^BENCH_JSON: ' "$log" | tail -n 1 | sed 's/^BENCH_JSON: //' || true)"
if [ -z "$json_line" ]; then
  echo "error: bench produced no BENCH_JSON line" >&2
  exit 1
fi
printf '%s\n' "$json_line" > "$out"
echo "wrote $out"
