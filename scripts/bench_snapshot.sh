#!/usr/bin/env bash
# Run the micro_lockfree bench plus a traced stress run and snapshot
# their machine-readable summaries (every BENCH_JSON line, merged into
# one object) into a JSON baseline for the perf trajectory.
#
# Usage: scripts/bench_snapshot.sh [output.json]   (default: BENCH_micro.json
# at the repo root). The full human-readable reports stream to stdout.
# Trace exports (chrome-trace / NDJSON / metrics JSON) land next to the
# snapshot as <output>.trace.{chrome.json,ndjson,metrics.json}.
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out="${1:-$repo_root/BENCH_micro.json}"
case "$out" in
  /*) ;;
  *) out="$PWD/$out" ;;
esac

log="$(mktemp)"
trap 'rm -f "$log"' EXIT

(cd "$repo_root/rust" && cargo bench --bench micro_lockfree) | tee "$log"

# Stage-latency attribution on the same workload family: a traced
# packet stress on the sim plane (deterministic), exporting alongside
# the snapshot. Its BENCH_JSON line rides into the merged object.
trace_prefix="${out%.json}.trace"
(cd "$repo_root/rust" \
  && cargo run --release -- trace \
       --kind packet --tx 400 --cores 2 --plane sim --out "$trace_prefix") \
  | tee -a "$log"

# Every BENCH_JSON line is a flat JSON object; merge them into a single
# object, last key wins on collision. Host metadata keys come last so a
# snapshot always records where it was taken.
mapfile -t json_lines < <(grep '^BENCH_JSON: ' "$log" | sed 's/^BENCH_JSON: //')
if [ "${#json_lines[@]}" -eq 0 ]; then
  echo "error: bench produced no BENCH_JSON line" >&2
  exit 1
fi
host_cores="$(nproc 2>/dev/null || getconf _NPROCESSORS_ONLN 2>/dev/null || echo 0)"
host_os="$(uname -sr 2>/dev/null || echo unknown)"
git_sha="$(git -C "$repo_root" rev-parse --short HEAD 2>/dev/null || echo unknown)"
json_lines+=("{\"host_cores\": ${host_cores}, \"host_os\": \"${host_os}\", \"git_sha\": \"${git_sha}\"}")
merged="$(printf '%s\n' "${json_lines[@]}" \
  | sed 's/^[[:space:]]*{//; s/}[[:space:]]*$//' \
  | paste -sd ',' -)"
printf '{%s}\n' "$merged" > "$out"

# The merged object must stay machine-readable.
if command -v python3 >/dev/null 2>&1; then
  python3 -c 'import json, sys; json.load(open(sys.argv[1]))' "$out"
fi

# Required rows: the PR-over-PR trajectory keys must all be present.
for key in spsc_ratio spsc_batch_ratio empty_pop_ns pkt_queue_mps pkt_ring_mps pkt_ring_vs_queue \
           stress_pkt_timeouts stress_pkt_poisons stress_pkt_leases_reclaimed \
           mpmc_scaling_c1_mps mpmc_scaling_c2_mps mpmc_scaling_c4_mps mpmc_scaling_batch_ratio \
           mpmc_steal_c1_mps mpmc_steal_c2_mps mpmc_steal_c4_mps mpmc_steal_vs_shared \
           mpmc_steal_skew_mps \
           trace_events trace_send_commit_p99_ns trace_wakeup_recv_p99_ns trace_replay_pass \
           trace_lane_peak liveness_suspects liveness_confirms liveness_false_suspects \
           liveness_fence_rejects host_cores host_os git_sha; do
  if ! grep -q "\"$key\"" "$out"; then
    echo "error: BENCH_micro snapshot is missing \"$key\"" >&2
    exit 1
  fi
done

# The metrics export must carry the per-lane drop watermarks.
if ! grep -q '"lanes"' "$trace_prefix.metrics.json"; then
  echo "error: trace metrics export is missing the per-lane watermark block" >&2
  exit 1
fi
echo "wrote $out"
