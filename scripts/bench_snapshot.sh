#!/usr/bin/env bash
# Run the micro_lockfree bench and snapshot its machine-readable summary
# (every BENCH_JSON line, merged into one object) into a JSON baseline
# for the perf trajectory.
#
# Usage: scripts/bench_snapshot.sh [output.json]   (default: BENCH_micro.json
# at the repo root). The full human-readable bench report streams to stdout.
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out="${1:-$repo_root/BENCH_micro.json}"
case "$out" in
  /*) ;;
  *) out="$PWD/$out" ;;
esac

log="$(mktemp)"
trap 'rm -f "$log"' EXIT

(cd "$repo_root/rust" && cargo bench --bench micro_lockfree) | tee "$log"

# The bench emits one BENCH_JSON line per section (NBB coherence row,
# connected-channel ring-vs-queue row, ...). Each is a flat JSON object;
# merge them into a single object, last key wins on collision.
mapfile -t json_lines < <(grep '^BENCH_JSON: ' "$log" | sed 's/^BENCH_JSON: //')
if [ "${#json_lines[@]}" -eq 0 ]; then
  echo "error: bench produced no BENCH_JSON line" >&2
  exit 1
fi
merged="$(printf '%s\n' "${json_lines[@]}" \
  | sed 's/^[[:space:]]*{//; s/}[[:space:]]*$//' \
  | paste -sd ',' -)"
printf '{%s}\n' "$merged" > "$out"

# The merged object must stay machine-readable.
if command -v python3 >/dev/null 2>&1; then
  python3 -c 'import json, sys; json.load(open(sys.argv[1]))' "$out"
fi

# Required rows: the PR-over-PR trajectory keys must all be present.
for key in spsc_ratio spsc_batch_ratio empty_pop_ns pkt_queue_mps pkt_ring_mps pkt_ring_vs_queue; do
  if ! grep -q "\"$key\"" "$out"; then
    echo "error: BENCH_micro snapshot is missing \"$key\"" >&2
    exit 1
  fi
done
echo "wrote $out"
